"""Fault-tolerant serving: deterministic fault injection, bounded
retry/requeue, SLO deadlines, and graceful degradation to retrieval
priors — unit coverage of serving.faults plus stream-level integration
through both serve runtimes."""
import numpy as np
import pytest

from repro.api import EngineConfig, RouteRequest, ScopeEngine
from repro.api.cache import CachedPrediction, PredictionCache
from repro.core.estimator import (
    FallbackEstimator, ParsedBatch, ReasoningEstimator)
from repro.core.status import STATUS_DEGRADED, STATUS_FAILED, STATUS_OK
from repro.data.datasets import build_scope_data
from repro.serving.faults import (
    FaultInjector, FaultPlan, FaultSpec, InjectedFault)
from repro.serving.runtime import ServeRuntime
from repro.serving.scheduler import (
    BucketConfig, Microbatch, MicrobatchScheduler)


# ---------------------------------------------------------------------------
# FaultSpec / FaultPlan / FaultInjector units
# ---------------------------------------------------------------------------
def test_fault_spec_and_plan_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("gpu_on_fire", 0)
    with pytest.raises(ValueError, match="index"):
        FaultSpec("dispatch", -1)
    with pytest.raises(ValueError, match="duplicate"):
        FaultPlan([FaultSpec("parse", 3), FaultSpec("parse", 3)])
    assert not FaultPlan.none()
    assert FaultPlan([FaultSpec("pool", 0)])


def test_fault_plan_seeded_deterministic():
    rates = {"dispatch": 0.5, "parse": 0.25, "stall": 0.25}
    p1 = FaultPlan.seeded(7, rates=rates, stall_s=3.0)
    p2 = FaultPlan.seeded(7, rates=rates, stall_s=3.0)
    assert p1.specs == p2.specs and p1
    assert FaultPlan.seeded(8, rates=rates, stall_s=3.0).specs != p1.specs
    stalls = [s for s in p1.specs if s.site == "stall"]
    assert stalls and all(s.arg == 3.0 for s in stalls)
    with pytest.raises(ValueError, match="rate"):
        FaultPlan.seeded(0, rates={"dispatch": 1.5})


def _pb(n):
    return ParsedBatch(
        y_hat=np.ones(n, int), len_hat=np.full(n, 9.0),
        well_formed=np.ones(n, bool), p_conf=np.full(n, 0.9),
        pred_tokens=np.full(n, 5), rationale_len=np.full(n, 2))


def test_injector_noop_default_is_inert():
    """No plan (and FaultPlan.none()) must not perturb anything: no spec
    ever fires and corrupt_parse returns the batch object unchanged."""
    for inj in (FaultInjector(), FaultInjector(FaultPlan.none())):
        for _ in range(16):
            assert inj.tick("dispatch") is None
            inj.raise_if("segment")         # never raises
        batch = _pb(3)
        assert inj.corrupt_parse(batch) is batch
        assert inj.fired == 0 and inj.stall_offset == 0.0


def test_injector_fires_planned_events_by_index():
    inj = FaultInjector(FaultPlan([FaultSpec("dispatch", 1),
                                   FaultSpec("stall", 0, arg=2.5)]))
    inj.raise_if("dispatch")                # event 0: clean
    with pytest.raises(InjectedFault, match="dispatch"):
        inj.raise_if("dispatch")            # event 1: fires
    assert inj.tick("stall") is not None
    assert inj.stall_offset == 2.5
    assert inj.fired == 2


def test_corrupt_parse_scrambles_whole_group():
    inj = FaultInjector(FaultPlan([FaultSpec("parse", 1)]))
    first = _pb(3)
    assert inj.corrupt_parse(first) is first        # event 0: untouched
    got = inj.corrupt_parse(_pb(3))                 # event 1: garbage
    assert len(got) == 3 and not got.well_formed.any()
    assert (got.p_conf == 0.5).all() and (got.y_hat == 0).all()
    assert (got.pred_tokens == 5).all()     # tokens were genuinely spent
    assert (got.status == STATUS_OK).all()  # malformed, not degraded


# ---------------------------------------------------------------------------
# Scheduler: requeue / cancel accounting
# ---------------------------------------------------------------------------
def test_scheduler_requeue_and_cancel_accounting():
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(2, 4)),
                                clock=lambda: 0.0)
    sched.submit("a", [5] * 4)
    sched.submit("b", [5] * 4)
    assert sched.flush() and sched.stats.emitted == 2
    sched.requeue("a", [5] * 4)             # retry: not a new submission
    assert sched.stats.submitted == 2 and sched.stats.requeued == 1
    assert sched.cancel("a") == [5] * 4     # queued row: removed
    assert sched.cancel("a") is None        # exactly-once
    assert sched.cancel("zzz") is None      # unknown tag
    assert len(sched) == 0


# ---------------------------------------------------------------------------
# FallbackEstimator: degraded answers from retrieval priors
# ---------------------------------------------------------------------------
def test_fallback_estimator_prior_predictions(world, library):
    model = next(m.name for m in world.pool if m.seen)
    fp = library.get(model)
    sims = np.array([[0.9, 0.5, 0.1], [0.0, 0.0, 0.0]])
    idx = np.array([[0, 1, 2], [3, 4, 5]])
    out = FallbackEstimator(library).predict_pairs(sims, idx,
                                                   [model, model])
    assert (out.status == STATUS_DEGRADED).all()
    assert out.well_formed.all()            # priced at the predicted len,
    assert (out.pred_tokens == 0).all()     # zero decode tokens spent
    assert ((out.p_conf >= 0.0) & (out.p_conf <= 1.0)).all()
    np.testing.assert_array_equal(out.y_hat,
                                  (out.p_conf >= 0.5).astype(int))
    w = sims[0] / sims[0].sum()             # similarity-weighted priors
    np.testing.assert_allclose(out.p_conf[0],
                               w @ np.asarray(fp.y, float)[idx[0]])
    np.testing.assert_allclose(out.len_hat[0],
                               w @ np.asarray(fp.tokens, float)[idx[0]])
    # zero-similarity rows fall back to uniform anchor weighting
    np.testing.assert_allclose(out.p_conf[1],
                               np.asarray(fp.y, float)[idx[1]].mean())


def test_fallback_failed_pairs_shape():
    out = FallbackEstimator.failed_pairs(2)
    assert (out.status == STATUS_FAILED).all()
    assert not out.well_formed.any()        # pessimistic-fallback pricing
    assert (out.p_conf == 0.0).all() and (out.pred_tokens == 0).all()


# ---------------------------------------------------------------------------
# Cache: the tier-0/tier-1 degraded-overwrite scheme
# ---------------------------------------------------------------------------
def test_cache_degraded_tier_overwrite_rules():
    cache = PredictionCache()
    ok = CachedPrediction(1, 9.0, True, 0.8, 5, 7, status=STATUS_OK)
    deg = CachedPrediction(0, 3.0, True, 0.4, 0, 7,
                           status=STATUS_DEGRADED)
    cache.put(1, "m", "v", ok)
    cache.put(1, "m", "v", deg)             # degraded never clobbers OK
    assert cache._store[(1, "m", "v")].status == STATUS_OK
    cache.put(2, "m", "v", deg)
    deg2 = CachedPrediction(1, 4.0, True, 0.6, 0, 7,
                            status=STATUS_DEGRADED)
    cache.put(2, "m", "v", deg2)            # degraded refresh is allowed
    assert cache._store[(2, "m", "v")].p_conf == 0.6
    cache.put_many([(2, "m", "v")], [ok])   # a late real decode heals
    assert cache._store[(2, "m", "v")].status == STATUS_OK
    cache.put_many([(2, "m", "v")], [deg])  # and stays healed
    assert cache._store[(2, "m", "v")].status == STATUS_OK


# ---------------------------------------------------------------------------
# ServeRuntime: failure routing, close(), context manager
# ---------------------------------------------------------------------------
class _H:
    def __init__(self, name, ready=False, bad=False):
        self.name, self._ready, self._bad = name, ready, bad

    def is_ready(self):
        return self._ready

    def parse(self):
        if self._bad:
            raise ValueError("garbage result")
        return self.name


def _mb(name):
    return Microbatch(np.zeros((1, 4), np.int32), [name],
                      np.full((1,), 4, np.int32), (1, 4))


def test_serve_runtime_routes_dispatch_and_parse_failures():
    parsed, failed = [], []

    def dispatch(mb):
        if mb.tags[0] == "boom":
            raise RuntimeError("dispatch died")
        return _H(mb.tags[0], bad=mb.tags[0] == "bad")

    rt = ServeRuntime(dispatch, on_parsed=lambda mb, r: parsed.append(r),
                      max_pending=1,
                      on_failed=lambda mb, exc: failed.append(mb.tags[0]))
    rt.dispatch([_mb("boom"), _mb("a"), _mb("bad")])
    rt.finish()
    assert parsed == ["a"] and failed == ["boom", "bad"]
    assert rt.stats.failed == 2 and len(rt) == 0
    # without on_failed the exception stays loud (pre-fault behavior)
    rt2 = ServeRuntime(dispatch, on_parsed=lambda mb, r: None)
    with pytest.raises(RuntimeError, match="dispatch died"):
        rt2.dispatch([_mb("boom")])


def test_serve_runtime_close_and_context_manager():
    parsed = []

    def mk():
        return ServeRuntime(lambda mb: _H(mb.tags[0]),
                            on_parsed=lambda mb, r: parsed.append(r),
                            max_pending=4)

    with mk() as rt:                        # clean exit drains
        rt.dispatch([_mb("a"), _mb("b")])
        assert len(rt) == 2
    assert parsed == ["a", "b"] and len(rt) == 0

    parsed.clear()
    with pytest.raises(RuntimeError, match="stream died"):
        with mk() as rt:                    # error exit aborts, no parse
            rt.dispatch([_mb("c")])
            raise RuntimeError("stream died")
    assert parsed == [] and len(rt) == 0

    rt = mk()
    rt.dispatch([_mb("d")])
    rt.close(drain=False)                   # explicit abort
    assert parsed == [] and len(rt) == 0


# ---------------------------------------------------------------------------
# Stream integration: faults through the real engine
# ---------------------------------------------------------------------------
@pytest.fixture()
def chaos_engine(tiny_trained, world, retriever, library):
    cfg, params, _ = tiny_trained
    data = build_scope_data(world, n_queries=160, seed=9)

    def mk(max_new_tokens=6, **kw):
        return ScopeEngine.build(EngineConfig(
            estimator=ReasoningEstimator(cfg, params,
                                         max_new_tokens=max_new_tokens),
            retriever=retriever, library=library,
            models_meta={m: world.models[m] for m in data.models}, **kw))
    return mk, data


def _run(mk, data, n=6, ticks=2, *, use_cache=False, refill=False,
         segment_len=4, bucket_sizes=(1, 2, 4, 8), **cfg_kw):
    engine = mk(**cfg_kw)
    qs = [data.queries[int(q)] for q in data.test_qids[:n]]
    reqs = [RouteRequest([qs[i] for i in c])
            for c in np.array_split(np.arange(n), ticks)]
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=bucket_sizes))
    pools = list(engine.predict_stream(
        iter(reqs), scheduler=sched, use_cache=use_cache, refill=refill,
        segment_len=segment_len if refill else None))
    return engine, sched, pools


def _cat(pools, field):
    return np.concatenate([getattr(p, field) for p in pools], axis=0)


def test_dispatch_fault_retries_to_fault_free_parity(chaos_engine):
    """A failed dispatch requeues its rows; the retried decode lands the
    stream on the exact fault-free answers (token-derived fields bit-equal,
    confidences to ulp — retried rows ride different-shaped buckets)."""
    mk, data = chaos_engine
    _, _, ref = _run(mk, data)
    _, sched, got = _run(mk, data, max_retries=2,
                         fault_plan=FaultPlan([FaultSpec("dispatch", 0)]))
    st = sched.stats
    assert st.injected_faults == 1 and st.retries == 1
    assert st.requeued > 0 and st.quarantined == 0
    assert st.deadline_expired == 0 and st.degraded == 0
    assert (_cat(got, "status") == STATUS_OK).all()
    for f in ("y_hat", "len_hat", "well_formed", "cost_hat"):
        np.testing.assert_array_equal(_cat(got, f), _cat(ref, f),
                                      err_msg=f)
    np.testing.assert_allclose(_cat(got, "p_hat"), _cat(ref, "p_hat"),
                               atol=1e-6, rtol=1e-6)


def test_quarantine_answers_from_retrieval_priors(chaos_engine):
    """max_retries=0: the failed microbatch's pairs quarantine and come
    back DEGRADED from the FallbackEstimator — well-formed, zero decode
    overhead — and the degradation ledger balances."""
    mk, data = chaos_engine
    _, sched, got = _run(mk, data, max_retries=0,
                         fault_plan=FaultPlan([FaultSpec("dispatch", 0)]))
    st = sched.stats
    assert st.retries == 1 and st.requeued == 0
    assert st.quarantined > 0 and st.degraded == st.quarantined
    assert st.failed_pairs == 0 and st.deadline_expired == 0
    status = _cat(got, "status")
    n_deg = int((status == STATUS_DEGRADED).sum())
    assert n_deg == st.degraded + st.failed_pairs \
        == st.quarantined + st.deadline_expired
    assert not (status == STATUS_FAILED).any()
    deg = status == STATUS_DEGRADED
    assert _cat(got, "well_formed")[deg].all()
    assert (_cat(got, "pred_overhead")[deg] == 0).all()
    assert any(p.degraded_fraction > 0.0 for p in got)


def test_no_degrade_marks_pairs_failed(chaos_engine):
    """degrade=False: quarantined pairs are FAILED outright — malformed-
    estimate pricing instead of retrieval priors."""
    mk, data = chaos_engine
    _, sched, got = _run(mk, data, max_retries=0, degrade=False,
                         fault_plan=FaultPlan([FaultSpec("dispatch", 0)]))
    st = sched.stats
    assert st.quarantined > 0 and st.failed_pairs == st.quarantined
    assert st.degraded == 0
    status = _cat(got, "status")
    bad = status == STATUS_FAILED
    assert int(bad.sum()) == st.failed_pairs
    assert not (status == STATUS_DEGRADED).any()
    assert not _cat(got, "well_formed")[bad].any()


def test_deadline_expiry_degrades_and_late_parses_heal(chaos_engine):
    """An injected clock stall expires pairs past their SLO: each answers
    DEGRADED immediately.  A pair expiring while *queued* is cancelled
    outright — its decode never runs, so its prior-based cache entry
    (zero decode tokens) remains; a pair expiring *in flight* keeps
    decoding, and its late parse heals the entry to a full OK prediction.
    The single 8-wide bucket guarantees a queued remainder."""
    mk, data = chaos_engine
    engine, sched, got = _run(
        mk, data, use_cache=True, max_retries=2, deadline_ms=60_000.0,
        bucket_sizes=(8,),
        fault_plan=FaultPlan([FaultSpec("stall", 0, arg=1e6)]))
    st = sched.stats
    assert st.injected_faults == 1
    assert st.deadline_expired > 0 and st.degraded == st.deadline_expired
    assert st.quarantined == 0 and st.failed_pairs == 0
    status = _cat(got, "status")
    n_deg = int((status == STATUS_DEGRADED).sum())
    assert n_deg == st.degraded and not (status == STATUS_FAILED).any()
    entries = list(engine.cache._store.values())
    assert len(entries) == status.size
    stale = [e for e in entries if e.status != STATUS_OK]
    # cancelled-from-queue pairs: degraded entry, no decode ever ran
    assert 0 < len(stale) <= st.deadline_expired
    assert all(e.status == STATUS_DEGRADED and e.pred_tokens == 0
               for e in stale)
    # every pair whose decode ran has a full OK entry — never-expired
    # pairs directly, in-flight-expired pairs via the late-parse heal
    assert len(entries) - len(stale) >= status.size - st.deadline_expired


def test_parse_garbage_is_malformed_not_retried(chaos_engine):
    """Injected parse garbage flows through the malformed-estimate
    machinery (tokens were spent, the answer exists but is unusable): no
    retry, no degradation, just well_formed=False rows.  A 10-token
    budget lets the reference parse cleanly so the scrambled group is
    visible against it."""
    mk, data = chaos_engine
    _, _, ref = _run(mk, data, max_new_tokens=10)
    _, sched, got = _run(mk, data, max_new_tokens=10, max_retries=2,
                         fault_plan=FaultPlan([FaultSpec("parse", 0)]))
    st = sched.stats
    assert st.injected_faults == 1
    assert st.retries == 0 and st.quarantined == 0
    assert st.deadline_expired == 0 and st.degraded == 0
    assert (_cat(got, "status") == STATUS_OK).all()
    n_bad = int((~_cat(got, "well_formed")).sum())
    assert n_bad > int((~_cat(ref, "well_formed")).sum())


def test_refill_segment_and_pool_faults_recover(chaos_engine):
    """Refill path: a segment teardown requeues the whole live state and a
    KV-pool exhaustion fails a single row; both retry to the exact
    fault-free answers and the kv_exhausted_rows counter records the
    row-level failure."""
    mk, data = chaos_engine
    paged = {"kv_paged": True, "kv_page_size": 8}
    _, _, ref = _run(mk, data, refill=True, **paged)
    plan = FaultPlan([FaultSpec("segment", 1), FaultSpec("pool", 2)])
    _, sched, got = _run(mk, data, refill=True, max_retries=2,
                         fault_plan=plan, **paged)
    st = sched.stats
    assert st.injected_faults == 2
    assert st.kv_exhausted_rows == 1
    assert st.retries == 2 and st.requeued >= 2
    assert st.quarantined == 0
    assert (_cat(got, "status") == STATUS_OK).all()
    for f in ("y_hat", "len_hat", "well_formed", "cost_hat"):
        np.testing.assert_array_equal(_cat(got, f), _cat(ref, f),
                                      err_msg=f)
    np.testing.assert_allclose(_cat(got, "p_hat"), _cat(ref, "p_hat"),
                               atol=1e-6, rtol=1e-6)


def test_inflight_dedup_resolves_and_clears_across_ticks(chaos_engine):
    """Regression: the in-flight dedup map must drop a key once resolved.
    Duplicate pairs share one decode within a tick; with the cache
    evicting immediately (capacity=0) the same key is re-submitted in a
    later tick — a stale in-flight entry would strand it forever.  Runs
    both the retry and the quarantine resolution paths."""
    mk, data = chaos_engine
    qs = [data.queries[int(q)] for q in data.test_qids[:3]]
    plan = FaultPlan([FaultSpec("dispatch", 0)])
    for retries in (1, 0):
        engine = mk(fault_plan=plan, max_retries=retries)
        engine.cache.capacity = 0           # evict on every put
        sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
        reqs = [RouteRequest([qs[0], qs[0], qs[1]]),
                RouteRequest([qs[0], qs[1], qs[2], qs[2]])]
        pools = list(engine.predict_stream(iter(reqs), scheduler=sched,
                                           use_cache=True))
        assert len(pools) == 2
        # duplicate queries in one request share one resolution
        np.testing.assert_array_equal(pools[0].y_hat[0], pools[0].y_hat[1])
        np.testing.assert_array_equal(pools[1].y_hat[2], pools[1].y_hat[3])
        status = _cat(pools, "status")
        if retries:
            assert (status == STATUS_OK).all()
            assert sched.stats.quarantined == 0 and sched.stats.requeued > 0
        else:
            assert sched.stats.quarantined > 0
            assert (pools[0].status == STATUS_DEGRADED).any()
            assert (pools[1].status == STATUS_OK).all()
        assert len(engine.cache._store) == 0    # capacity 0 really evicts
