"""Distribution: sharding specs are divisibility-safe; a tiny model jits on
a small multi-device mesh (subprocess, isolated device-count flag)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed import sharding as shd
from repro.launch import specs as S

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    """Just enough of a Mesh for spec construction."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    mesh = FakeMesh((16, 16), ("data", "model"))
    params_sh = S.abstract_params(cfg)
    specs = shd.param_specs(mesh, params_sh)
    flat_p = jax.tree_util.tree_leaves(params_sh)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    sizes = {"data": 16, "model": 16}
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s, strict=True):
        for dim, ax in zip(leaf.shape, tuple(spec), strict=False):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, (arch, leaf.shape, spec)


def test_cache_specs_divisible_batch1():
    """long_500k: batch=1 must not be sharded; sequence takes the axes."""
    from repro.configs import INPUT_SHAPES
    cfg = S.resolved_config(get_config("gemma2-2b"), INPUT_SHAPES["long_500k"])
    mesh = FakeMesh((16, 16), ("data", "model"))
    caches = jax.eval_shape(
        lambda: __import__("repro.models.model", fromlist=["m"]).init_cache(
            cfg, 1, 524288))
    specs = shd.cache_specs(mesh, caches)
    flat_c = jax.tree_util.tree_leaves(caches)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    sizes = {"data": 16, "model": 16}
    for leaf, spec in zip(flat_c, flat_s, strict=True):
        for dim, ax in zip(leaf.shape, tuple(spec), strict=False):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, (leaf.shape, spec)


SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import model as M
from repro.distributed import sharding as shd
from repro.models.common import activation_mesh

cfg = get_config("internlm2-1.8b").reduced(d_model=256, num_heads=4)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = M.init_params(jax.random.PRNGKey(0), cfg)
pspecs = shd.param_specs(mesh, params)
ns = lambda s: NamedSharding(mesh, s)
p_sh = jax.tree.map(ns, pspecs, is_leaf=lambda x: isinstance(x, P))
params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, p_sh)
batch = {"tokens": jax.device_put(
    jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
    ns(P("data", None)))}
with activation_mesh(mesh, shd.activation_rules(mesh)):
    loss, _ = jax.jit(lambda p, b: M.loss_fn(p, cfg, b))(params, batch)
print(json.dumps({"loss": float(loss), "finite": bool(jnp.isfinite(loss))}))
"""


def test_tiny_model_runs_on_8_device_mesh():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["finite"]
