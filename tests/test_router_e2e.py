"""End-to-end SCOPE routing behaviour on the trained tiny estimator,
through the ``repro.api`` engine + policy surface."""
import numpy as np
import pytest

from repro.api import (
    EngineConfig, FixedAlphaPolicy, RouteRequest, ScopeEngine,
    SetBudgetPolicy)
from repro.core.estimator import ReasoningEstimator
from repro.core.evaluation import evaluate_choices


def _route(engine, pool, alpha):
    return np.argmax(engine.utilities(pool, alpha), axis=1)


@pytest.fixture(scope="module")
def router_setup(tiny_trained, scope_data, library, retriever):
    cfg, params, _ = tiny_trained
    est = ReasoningEstimator(cfg, params)
    world = scope_data.world
    engine = ScopeEngine.build(EngineConfig(
        estimator=est, retriever=retriever, library=library,
        models_meta={m: world.models[m] for m in scope_data.models}))
    qids = scope_data.test_qids[:10]
    queries = [scope_data.queries[int(q)] for q in qids]
    pool = engine.predict(RouteRequest(queries, models=scope_data.models))
    return engine, pool, qids


def test_pool_predictions_shapes(router_setup, scope_data):
    engine, pool, qids = router_setup
    Q, M = len(qids), len(scope_data.models)
    assert pool.p_hat.shape == (Q, M)
    assert np.all((pool.p_hat >= 0) & (pool.p_hat <= 1))
    assert np.all(pool.cost_hat > 0)
    assert pool.pred_overhead.sum() > 0


def test_alpha_zero_is_cheaper_than_alpha_one(router_setup, scope_data):
    engine, pool, qids = router_setup
    ch0 = _route(engine, pool, 0.0)
    ch1 = _route(engine, pool, 1.0)
    ev0 = evaluate_choices(scope_data, qids, scope_data.models, ch0)
    ev1 = evaluate_choices(scope_data, qids, scope_data.models, ch1)
    assert ev0.total_cost <= ev1.total_cost + 1e-9


def test_budget_policy_respects_budget(router_setup, scope_data):
    engine, pool, qids = router_setup
    tight = float(np.sort(pool.cost_hat.min(axis=1)).sum() * 1.5)
    d = engine.decide(pool, SetBudgetPolicy(tight))
    if d.info["feasible"]:
        assert d.info["expected_cost"] <= tight + 1e-9
    assert 0.0 <= d.alpha <= 1.0
    assert d.choices.shape == (len(qids),)


def test_calibration_changes_decisions_smoothly(router_setup):
    engine, pool, _ = router_setup
    u_with = engine.utilities(pool, 0.5, with_calibration=True)
    u_without = engine.utilities(pool, 0.5, with_calibration=False)
    assert u_with.shape == u_without.shape
    assert not np.allclose(u_with, u_without)       # prior has an effect


def test_engine_serve_report(router_setup, scope_data):
    engine, pool, qids = router_setup
    d = engine.decide(pool, FixedAlphaPolicy(0.7))
    rep = engine.execute(scope_data, qids, pool, d, "fixed_alpha")
    assert 0.0 <= rep.accuracy <= 1.0
    assert abs(sum(rep.per_model_share.values()) - 1.0) < 1e-9
    assert rep.overhead_tokens > 0


def test_unseen_model_routable_without_retraining(tiny_trained, scope_data,
                                                  library, retriever):
    """The core SCOPE claim: onboard an unseen model via fingerprint only."""
    cfg, params, _ = tiny_trained
    world = scope_data.world
    unseen = "claude-sonnet-4.5"
    if unseen not in library:
        library.onboard(world, unseen, seed=99)
    est = ReasoningEstimator(cfg, params)
    models = scope_data.models + [unseen]
    engine = ScopeEngine.build(EngineConfig(
        estimator=est, retriever=retriever, library=library,
        models_meta={m: world.models[m] for m in models}))
    queries = [scope_data.queries[int(q)] for q in scope_data.test_qids[:6]]
    pool = engine.predict(RouteRequest(queries, models=models))
    assert pool.p_hat.shape == (6, len(models))
    # at alpha=1 the strongest (unseen) model should attract some traffic
    ch1 = _route(engine, pool, 1.0)
    assert np.all(ch1 >= 0)
