"""Continuous-batching serve runtime: per-row ragged decode, DecodeState
segments + slot refill, the double-buffered ServeRuntime, and the engine's
overlapped predict_stream (incl. ragged-length grid parity)."""
import numpy as np
import pytest

import jax

from repro.api import EngineConfig, RouteRequest, ScopeEngine
from repro.core.estimator import (
    DecodeHandle, ReasoningEstimator, parse_generations)
from repro.data import tokenizer as tok
from repro.data.datasets import build_scope_data
from repro.serving import sampler
from repro.serving.runtime import ServeRuntime
from repro.serving.scheduler import BucketConfig, Microbatch, MicrobatchScheduler


# ---------------------------------------------------------------------------
# Per-row positions / ragged prompt lengths in the sampler
# ---------------------------------------------------------------------------
def test_generate_ragged_lengths_match_unpadded(tiny_trained):
    """Sub-bucket rows reproduce the unpadded run: token stream bit-exact,
    decision logits to f32 ulp (attention reductions span the bucket width,
    so last-bit equality across widths is not representable)."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(0)
    lens = [15, 20, 9, 20]
    L = max(lens)
    rows = [rng.integers(3, 100, size=ln).astype(np.int32) for ln in lens]
    padded = np.zeros((len(rows), L), np.int32)
    for i, r in enumerate(rows):
        padded[i, : len(r)] = r
    g, d = sampler.generate(params, cfg, padded, max_new_tokens=6,
                            prompt_lens=lens)
    for i, r in enumerate(rows):
        gi, di = sampler.generate(params, cfg, r[None], max_new_tokens=6)
        np.testing.assert_array_equal(g[i], gi[0], err_msg=f"row {i} tokens")
        np.testing.assert_allclose(d[i], di[0], atol=5e-6, rtol=1e-6,
                                   err_msg=f"row {i} decision logits")


def test_generate_full_length_rows_bit_identical_under_lens(tiny_trained):
    """A row whose true length equals the bucket is untouched by the
    per-row machinery: same batch, with vs without prompt_lens."""
    cfg, params, _ = tiny_trained
    prompts = np.random.default_rng(1).integers(
        3, 100, size=(3, 20)).astype(np.int32)
    g0, d0 = sampler.generate(params, cfg, prompts, max_new_tokens=5)
    g1, d1 = sampler.generate(params, cfg, prompts, max_new_tokens=5,
                              prompt_lens=[20, 20, 20])
    np.testing.assert_array_equal(g0, g1)
    np.testing.assert_array_equal(d0, d1)


def test_prompt_lens_validation(tiny_trained):
    cfg, params, _ = tiny_trained
    prompts = np.ones((2, 10), np.int32)
    with pytest.raises(ValueError, match="prompt_lens"):
        sampler.generate(params, cfg, prompts, prompt_lens=[5])
    with pytest.raises(ValueError, match="prompt_lens"):
        sampler.generate(params, cfg, prompts, prompt_lens=[5, 11])
    with pytest.raises(ValueError, match="prompt_lens"):
        sampler.generate(params, cfg, prompts, prompt_lens=[0, 10])


# ---------------------------------------------------------------------------
# DecodeState: chunked segments + slot refill
# ---------------------------------------------------------------------------
def test_decode_segments_match_one_shot(tiny_trained):
    cfg, params, _ = tiny_trained
    prompts = np.random.default_rng(2).integers(
        3, 100, size=(4, 18)).astype(np.int32)
    g1, d1 = sampler.generate(params, cfg, prompts, max_new_tokens=8)
    # warm the per-segment-length executables, then re-run the segment loop
    # under a device->host transfer guard: the hot loop must dispatch with
    # no implicit sync (runtime complement of scopelint's static pass); the
    # np.asarray conversions below are the intended syncs, outside the guard
    warm = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
    for steps in (3, 3, 2):
        warm, _, _ = sampler.decode_segment(params, cfg, warm, steps)
    segs = []
    with jax.transfer_guard_device_to_host("disallow"):
        state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
        for steps in (3, 3, 2):
            state, g, d = sampler.decode_segment(params, cfg, state, steps)
            segs.append((g, d))
    gs = [np.asarray(g) for g, _ in segs]
    ds = [np.asarray(d) for _, d in segs]
    np.testing.assert_array_equal(np.concatenate(gs, axis=1), g1)
    np.testing.assert_array_equal(np.concatenate(ds, axis=1), d1)
    assert int(state.positions[0]) == 18 + 8 and state.used == 18 + 8


def test_decode_segments_match_one_shot_temperature(tiny_trained):
    """The sampling key is carried across segments — chunking must not
    change the stochastic stream."""
    cfg, params, _ = tiny_trained
    prompts = np.random.default_rng(3).integers(
        3, 100, size=(3, 16)).astype(np.int32)
    key = jax.random.PRNGKey(7)
    g1, _ = sampler.generate(params, cfg, prompts, max_new_tokens=8,
                             temperature=0.8, rng=key)
    warm = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8,
                                 rng=key)
    for steps in (5, 3):
        warm, _, _ = sampler.decode_segment(params, cfg, warm, steps,
                                            temperature=0.8)
    segs = []
    with jax.transfer_guard_device_to_host("disallow"):
        state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8,
                                      rng=key)
        for steps in (5, 3):
            state, g, _ = sampler.decode_segment(params, cfg, state, steps,
                                                 temperature=0.8)
            segs.append(g)
    gs = [np.asarray(g) for g in segs]
    np.testing.assert_array_equal(np.concatenate(gs, axis=1), g1)


def test_refill_slot_between_segments(tiny_trained):
    """A drained slot refilled with a fresh prompt decodes exactly like a
    standalone run of that prompt, and the other rows are untouched."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(4)
    prompts = rng.integers(3, 100, size=(4, 18)).astype(np.int32)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
    state, _, _ = sampler.decode_segment(params, cfg, state, 4)

    new_prompt = rng.integers(3, 100, size=18).astype(np.int32)
    state = sampler.refill_slot(params, cfg, state, 2, new_prompt)
    assert int(state.positions[2]) == 18 and not bool(state.done[2])
    state, g, d = sampler.decode_segment(params, cfg, state, 4)

    # reference at the same batch size (a b=1 run picks a different gemm
    # path whose accumulation differs in the last ulp): token stream must
    # be bit-exact, decision logits to f32 ulp
    g_ref, d_ref = sampler.generate(params, cfg,
                                    np.repeat(new_prompt[None], 4, 0),
                                    max_new_tokens=4)
    np.testing.assert_array_equal(np.asarray(g)[2], g_ref[0])
    np.testing.assert_allclose(np.asarray(d)[2], d_ref[0],
                               atol=5e-6, rtol=1e-6)

    # untouched rows continue bit-identically vs a no-refill run
    s2 = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
    s2, _, _ = sampler.decode_segment(params, cfg, s2, 4)
    s2, g2, _ = sampler.decode_segment(params, cfg, s2, 4)
    np.testing.assert_array_equal(
        np.asarray(g)[[0, 1, 3]], np.asarray(g2)[[0, 1, 3]])


def test_refill_slot_padded_prompt_matches_exact(tiny_trained):
    """A refill prompt padded to the warmed bucket width (with its true
    prompt_len) decodes bit-identically to an exact-length refill: pad
    garbage in the cache tail is masked out by the per-row valid length."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(11)
    prompts = rng.integers(3, 100, size=(4, 18)).astype(np.int32)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
    state, _, _ = sampler.decode_segment(params, cfg, state, 4)

    new_prompt = rng.integers(3, 100, size=12).astype(np.int32)
    padded = np.zeros(18, np.int32)
    padded[:12] = new_prompt
    s_exact = sampler.refill_slot(params, cfg, state, 2, new_prompt)
    s_pad = sampler.refill_slot(params, cfg, state, 2, padded,
                                prompt_len=12)
    assert int(s_pad.positions[2]) == 12
    _, g_e, d_e = sampler.decode_segment(params, cfg, s_exact, 4)
    _, g_p, d_p = sampler.decode_segment(params, cfg, s_pad, 4)
    np.testing.assert_array_equal(np.asarray(g_p), np.asarray(g_e))
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_e),
                               atol=5e-6, rtol=1e-6)


def test_refill_slots_batched_matches_sequential(tiny_trained):
    """One batched refill_slots call (padded to the warmed (b, L) prefill
    shape) equals sequential single-slot refills."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(12)
    prompts = rng.integers(3, 100, size=(4, 18)).astype(np.int32)
    fresh = rng.integers(3, 100, size=(2, 14)).astype(np.int32)

    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
    state, _, _ = sampler.decode_segment(params, cfg, state, 4)

    mat = np.zeros((4, 18), np.int32)           # padded to (b, L)
    mat[0, :14] = fresh[0]
    mat[1, :14] = fresh[1]
    s_batch = sampler.refill_slots(params, cfg, state, [1, 3], mat,
                                   prompt_lens=[14, 14])
    s_seq = sampler.refill_slot(params, cfg, state, 1, fresh[0])
    s_seq = sampler.refill_slot(params, cfg, s_seq, 3, fresh[1])
    _, g_b, d_b = sampler.decode_segment(params, cfg, s_batch, 4)
    _, g_s, d_s = sampler.decode_segment(params, cfg, s_seq, 4)
    np.testing.assert_array_equal(np.asarray(g_b), np.asarray(g_s))
    np.testing.assert_allclose(np.asarray(d_b), np.asarray(d_s),
                               atol=5e-6, rtol=1e-6)


def test_decode_segment_fused_refill_matches_unfused(tiny_trained):
    """decode_segment(refill=(mask, prompts, lens)) — prefill + merge +
    scan in one executable — is bit-identical to refill_slots followed by
    a plain segment (tokens AND decision logits: same math, one launch)."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(13)
    prompts = rng.integers(3, 100, size=(4, 18)).astype(np.int32)
    fresh = rng.integers(3, 100, size=(2, 12)).astype(np.int32)

    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=16)
    state, _, _ = sampler.decode_segment(params, cfg, state, 4)

    mat = np.zeros((4, 18), np.int32)
    mat[1, :12] = fresh[0]
    mat[3, :12] = fresh[1]
    s_ref = sampler.refill_slots(params, cfg, state, [1, 3],
                                 np.concatenate([mat[1:2], mat[3:4],
                                                 mat[:2] * 0]),
                                 prompt_lens=[12, 12])
    s_ref, g_ref, d_ref = sampler.decode_segment(params, cfg, s_ref, 4)

    mask = np.array([False, True, False, True])
    s_fus, g_fus, d_fus = sampler.decode_segment(
        params, cfg, state, 4, refill=(mask, mat, [1, 12, 1, 12]))
    np.testing.assert_array_equal(np.asarray(g_fus), np.asarray(g_ref))
    np.testing.assert_array_equal(np.asarray(d_fus), np.asarray(d_ref))
    np.testing.assert_array_equal(np.asarray(s_fus.positions),
                                  np.asarray(s_ref.positions))
    # continuation stays aligned too
    _, g2f, _ = sampler.decode_segment(params, cfg, s_fus, 4)
    _, g2r, _ = sampler.decode_segment(params, cfg, s_ref, 4)
    np.testing.assert_array_equal(np.asarray(g2f), np.asarray(g2r))


def test_decode_segment_refill_guards(tiny_trained):
    cfg, params, _ = tiny_trained
    state = sampler.prefill_state(params, cfg, np.ones((2, 10), np.int32),
                                  max_new_tokens=8)
    mat = np.ones((2, 8), np.int32)
    with pytest.raises(ValueError, match="no rows"):
        sampler.decode_segment(params, cfg, state, 4,
                               refill=([False, False], mat, [8, 8]))
    with pytest.raises(ValueError, match="mask/prompts"):
        sampler.decode_segment(params, cfg, state, 4,
                               refill=([True], mat, [8]))
    with pytest.raises(ValueError, match="prompt_lens"):
        sampler.decode_segment(params, cfg, state, 4,
                               refill=([True, False], mat, [0, 8]))


def test_refill_slots_guards(tiny_trained):
    cfg, params, _ = tiny_trained
    prompts = np.ones((3, 10), np.int32)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=4)
    mat = np.ones((2, 8), np.int32)
    with pytest.raises(ValueError, match="duplicate"):
        sampler.refill_slots(params, cfg, state, [1, 1], mat)
    with pytest.raises(ValueError, match="out of range"):
        sampler.refill_slots(params, cfg, state, [0, 5], mat)
    with pytest.raises(ValueError, match="rows for only"):
        sampler.refill_slots(params, cfg, state, [0, 1, 2], mat)
    with pytest.raises(ValueError, match="prompt_len"):
        sampler.refill_slots(params, cfg, state, [0, 1], mat,
                             prompt_lens=[0, 8])


def test_refill_and_segment_guards(tiny_trained):
    cfg, params, _ = tiny_trained
    prompts = np.ones((2, 10), np.int32)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=4)
    with pytest.raises(ValueError, match="out of range"):
        sampler.refill_slot(params, cfg, state, 5, [1] * 8)
    with pytest.raises(ValueError, match="no decode room"):
        sampler.refill_slot(params, cfg, state, 0, [1] * 14)
    with pytest.raises(ValueError, match="overruns the cache"):
        sampler.decode_segment(params, cfg, state, 5)
    with pytest.raises(ValueError, match="positive"):
        sampler.decode_segment(params, cfg, state, 0)


def test_generate_requires_rng_for_stochastic_decoding(tiny_trained):
    """temperature>0 without an explicit key must raise: the old
    PRNGKey(0) fallback sampled the identical stream on every call."""
    cfg, params, _ = tiny_trained
    prompts = np.ones((1, 8), np.int32)
    with pytest.raises(ValueError, match="rng"):
        sampler.generate(params, cfg, prompts, max_new_tokens=2,
                         temperature=0.7)
    # greedy keeps its deterministic no-key default
    g1, _ = sampler.generate(params, cfg, prompts, max_new_tokens=2)
    g2, _ = sampler.generate(params, cfg, prompts, max_new_tokens=2)
    np.testing.assert_array_equal(g1, g2)


def test_estimator_batch_requires_rng_for_stochastic(tiny_trained):
    cfg, params, _ = tiny_trained
    est = ReasoningEstimator(cfg, params, max_new_tokens=4)
    prompts = [[5] * 12, [6] * 12]
    with pytest.raises(ValueError, match="rng"):
        est.predict_batch(prompts, temperature=0.9)
    out = est.predict_batch(prompts, temperature=0.9,
                            rng=jax.random.PRNGKey(3))
    assert len(out) == 2


def test_dispatch_batch_empty_returns_empty_parse(tiny_trained):
    cfg, params, _ = tiny_trained
    est = ReasoningEstimator(cfg, params, max_new_tokens=4)
    handle = est.dispatch_batch([])
    assert handle.is_ready()
    assert len(handle.parse()) == 0        # not a concatenate crash


def test_ragged_prompt_lens_rejected_for_ssm_backbone():
    """SSM/conv prefill state consumes right-pad tokens (no per-row
    masking), so sub-bucket lengths must fail loudly, not corrupt."""
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("mamba2-1.3b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.ones((2, 10), np.int32)
    with pytest.raises(ValueError, match="attention-only"):
        sampler.prefill_state(params, cfg, prompts, max_new_tokens=2,
                              prompt_lens=[6, 10])
    # full-length rows carry no pad into the state: still allowed
    sampler.prefill_state(params, cfg, prompts, max_new_tokens=2,
                          prompt_lens=[10, 10])


# ---------------------------------------------------------------------------
# ServeRuntime: FIFO parse order, capacity, sync/overlap paths
# ---------------------------------------------------------------------------
class _Handle:
    def __init__(self, name, ready, log):
        self.name = name
        self._ready = ready
        self.log = log

    def is_ready(self):
        return self._ready

    def parse(self):
        self.log.append(("parse", self.name))
        return self.name


def _mb(name):
    return Microbatch(np.zeros((1, 4), np.int32), [name],
                      np.full((1,), 4, np.int32), (1, 4))


def test_serve_runtime_fifo_and_capacity():
    log, parsed = [], []

    def dispatch(mb):
        log.append(("dispatch", mb.tags[0]))
        return _Handle(mb.tags[0], ready=False, log=log)

    rt = ServeRuntime(dispatch, on_parsed=lambda mb, r: parsed.append(r),
                      max_pending=1)
    rt.dispatch([_mb("a")])
    assert log == [("dispatch", "a")] and len(rt) == 1
    rt.dispatch([_mb("b")])            # capacity: parse a BEFORE launching b
    assert log == [("dispatch", "a"), ("parse", "a"), ("dispatch", "b")]
    assert parsed == ["a"] and len(rt) == 1
    rt.finish()
    assert parsed == ["a", "b"] and len(rt) == 0
    assert rt.stats.dispatched == 2 and rt.stats.parsed == 2


def test_serve_runtime_sync_mode_parses_immediately():
    log, parsed = [], []
    rt = ServeRuntime(
        lambda mb: _Handle(mb.tags[0], ready=True, log=log),
        on_parsed=lambda mb, r: parsed.append(r), max_pending=0)
    rt.dispatch([_mb("a"), _mb("b")])
    assert parsed == ["a", "b"] and len(rt) == 0


def test_serve_runtime_poll_parses_only_ready():
    log, parsed = [], []
    handles = {}

    def dispatch(mb):
        h = _Handle(mb.tags[0], ready=False, log=log)
        handles[mb.tags[0]] = h
        return h

    rt = ServeRuntime(dispatch, on_parsed=lambda mb, r: parsed.append(r),
                      max_pending=2)
    rt.dispatch([_mb("a"), _mb("b")])
    assert rt.poll() == 0 and parsed == []
    handles["b"]._ready = True         # b done, but a (older) still running:
    assert rt.poll() == 0              # FIFO order is never violated
    handles["a"]._ready = True
    assert rt.poll() == 2 and parsed == ["a", "b"]
    # duck-typed results (no is_ready/parse) degrade to the sync path
    rt2 = ServeRuntime(lambda mb: mb.tags[0],
                       on_parsed=lambda mb, r: parsed.append(r),
                       max_pending=0)
    rt2.dispatch([_mb("c")])
    assert parsed[-1] == "c"


# ---------------------------------------------------------------------------
# Engine: overlapped stream parity + ragged length-grid parity
# ---------------------------------------------------------------------------
@pytest.fixture()
def real_engine(tiny_trained, world, retriever, library):
    cfg, params, _ = tiny_trained
    data = build_scope_data(world, n_queries=160, seed=9)

    def mk(**kw):
        return ScopeEngine.build(EngineConfig(
            estimator=ReasoningEstimator(cfg, params, max_new_tokens=6),
            retriever=retriever, library=library,
            models_meta={m: world.models[m] for m in data.models}, **kw))
    return mk, data


def test_stream_overlap_modes_bit_identical(real_engine):
    """Overlap changes when the host blocks, never what it observes: the
    double-buffered and synchronous streams see the same microbatches and
    must agree bit-for-bit; both match batch ``predict`` decisions (same
    tokens; confidences to f32 ulp — the one-big-batch shape reduces in a
    different order on this backend)."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:6]]
    ticks = [queries[:2], queries[2:3], queries[3:6]]
    ref = mk().predict(RouteRequest(queries))

    got = {}
    for overlap in (True, False):
        sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
        pools = list(mk().predict_stream(
            (RouteRequest(t) for t in ticks), scheduler=sched,
            overlap=overlap))
        got[overlap] = (np.concatenate([p.p_hat for p in pools]),
                        np.concatenate([p.y_hat for p in pools]))
    np.testing.assert_array_equal(got[True][0], got[False][0])
    np.testing.assert_array_equal(got[True][1], got[False][1])
    np.testing.assert_array_equal(got[True][1], ref.y_hat)
    np.testing.assert_allclose(got[True][0], ref.p_hat,
                               atol=1e-6, rtol=1e-6)


def test_stream_length_grid_matches_exact_fit(real_engine):
    """Ragged lengths under a configured prompt_lens grid: sub-bucket rows
    ride padded buckets yet the decisions match the unpadded exact-fit
    path — token-derived fields exactly, confidence to f32 ulp."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:5]]
    ticks = [queries[:2], queries[2:5]]
    ref = mk().predict(RouteRequest(queries))

    prompt_len = len(mk()._prepare(RouteRequest(queries[:1]), False)
                     .prompts[0])
    grid = BucketConfig(batch_sizes=(1, 2, 4, 8),
                        prompt_lens=(prompt_len + 7,))
    sched = MicrobatchScheduler(grid)
    pools = list(mk().predict_stream((RouteRequest(t) for t in ticks),
                                     scheduler=sched))
    assert sched.stats.pad_tokens > 0          # the grid really padded
    y = np.concatenate([p.y_hat for p in pools])
    lh = np.concatenate([p.len_hat for p in pools])
    wf = np.concatenate([p.well_formed for p in pools])
    cost = np.concatenate([p.cost_hat for p in pools])
    p_hat = np.concatenate([p.p_hat for p in pools])
    np.testing.assert_array_equal(y, ref.y_hat)
    np.testing.assert_array_equal(lh, ref.len_hat)
    np.testing.assert_array_equal(wf, ref.well_formed)
    np.testing.assert_array_equal(cost, ref.cost_hat)   # true prompt lens
    np.testing.assert_allclose(p_hat, ref.p_hat, atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Per-row window parse: refilled rows start mid-buffer
# ---------------------------------------------------------------------------
def test_parse_generations_windows_match_gathered():
    """Windowed parse == plain parse of the hand-gathered windows, over a
    buffer whose rows sit at different offsets with different lengths."""
    rng = np.random.default_rng(5)
    T, N = 24, 6
    gen = rng.integers(0, 40, size=(N, T))
    dec = rng.normal(size=(N, T, 2))
    # plant a well-formed body at each row's own offset
    starts = np.array([0, 3, 8, 0, 15, 20])
    lens = np.array([6, 6, 6, 4, 6, 4])
    for i, s in enumerate(starts):
        gen[i, s: s + 3] = [tok.YES, tok.LEN_BASE + 2, tok.EOS]
        gen[i, s + 3: s + lens[i]] = tok.PAD
    ref_rows = []
    for i in range(N):
        w = gen[i, starts[i]: starts[i] + lens[i]]
        dw = dec[i, starts[i]: starts[i] + lens[i]]
        pad = np.full(int(lens.max()) - lens[i], tok.PAD)
        ref_rows.append(parse_generations(
            np.concatenate([w, pad])[None],
            np.concatenate([dw, np.zeros((len(pad), 2))])[None]))
    got = parse_generations(gen, dec, starts=starts, lens=lens)
    for i, ref in enumerate(ref_rows):
        assert got.y_hat[i] == ref.y_hat[0]
        assert got.len_hat[i] == ref.len_hat[0]
        assert got.well_formed[i] == ref.well_formed[0]
        assert got.pred_tokens[i] == ref.pred_tokens[0]
        np.testing.assert_allclose(got.p_conf[i], ref.p_conf[0])


def test_parse_generations_window_validation():
    gen = np.zeros((2, 8), int)
    dec = np.zeros((2, 8, 2))
    with pytest.raises(ValueError, match="inside"):
        parse_generations(gen, dec, starts=[0, 6], lens=[8, 4])
    with pytest.raises(ValueError, match="must be"):
        parse_generations(gen, dec, starts=[0], lens=[4, 4])


def test_decode_handle_windows(tiny_trained):
    """DecodeHandle.parse with windows == parsing each row's slice."""
    cfg, params, _ = tiny_trained
    prompts = np.random.default_rng(6).integers(
        3, 100, size=(3, 16)).astype(np.int32)
    g, d = sampler.generate(params, cfg, prompts, max_new_tokens=8)
    windows = [(0, 8), (2, 6), (4, 4)]
    got = DecodeHandle([(g, d)], windows=windows).parse()
    for i, (s, ln) in enumerate(windows):
        pad = 8 - ln
        ref = parse_generations(
            np.concatenate([g[i, s: s + ln], np.full(pad, tok.PAD)])[None],
            np.concatenate([d[i, s: s + ln], np.zeros((pad, 2))])[None])
        assert got.y_hat[i] == ref.y_hat[0]
        assert got.pred_tokens[i] == ref.pred_tokens[0]
        np.testing.assert_allclose(got.p_conf[i], ref.p_conf[0])


# ---------------------------------------------------------------------------
# SlotRun: segment-chunked decode with mid-batch refill
# ---------------------------------------------------------------------------
def _drive_slot_run(est, prompts, tags, extra, *, segment_len):
    """Step a SlotRun to completion, admitting ``extra`` = [(tag, prompt)]
    into slots as they drain; returns {tag: per-field dict}."""
    run = est.open_slots(np.asarray(prompts, np.int32), tags=list(tags),
                         segment_len=segment_len)
    queue = list(extra)
    results = {}
    while not run.finished or queue:
        if queue and run.free_rows() and run.can_admit():
            n = min(len(queue), len(run.free_rows()))
            run.admit([(t, p, len(p)) for t, p in queue[:n]])
            del queue[:n]
        assert not run.finished, "queue left but horizon exhausted"
        tags_done, batch = run.step()
        for i, t in enumerate(tags_done):
            results[t] = {f: getattr(batch, f)[i] for f in
                          ("y_hat", "len_hat", "well_formed", "p_conf",
                           "pred_tokens", "rationale_len")}
    return results, run


def test_slot_run_refilled_rows_match_standalone(tiny_trained):
    """Every request served through a SlotRun — original rows and
    mid-batch refills alike — parses identically to a standalone
    whole-batch run of the same prompts."""
    cfg, params, _ = tiny_trained
    est = ReasoningEstimator(cfg, params, max_new_tokens=8)
    rng = np.random.default_rng(7)
    prompts = rng.integers(3, 100, size=(4, 18)).astype(np.int32)
    extra = rng.integers(3, 100, size=(3, 18)).astype(np.int32)

    results, run = _drive_slot_run(
        est, prompts, tags=["a", "b", "c", "d"],
        extra=[("e", list(extra[0])), ("f", list(extra[1])),
               ("g", list(extra[2]))], segment_len=4)
    assert set(results) == set("abcdefg")
    assert run.slot_steps_total > 0
    assert run.refill_steps > 0

    ref = est.predict_batch(
        [list(p) for p in np.concatenate([prompts, extra])])
    for i, t in enumerate("abcdefg"):
        got = results[t]
        assert got["y_hat"] == ref.y_hat[i], t
        assert got["len_hat"] == ref.len_hat[i], t
        assert got["well_formed"] == ref.well_formed[i], t
        assert got["pred_tokens"] == ref.pred_tokens[i], t
        assert got["rationale_len"] == ref.rationale_len[i], t
        np.testing.assert_allclose(got["p_conf"], ref.p_conf[i],
                                   atol=1e-6, rtol=1e-6, err_msg=t)


def test_slot_run_partial_bucket_has_free_slots(tiny_trained):
    """Rows beyond the real tags of a partially-filled opening bucket are
    immediately-free slots — a refill target from boundary zero."""
    cfg, params, _ = tiny_trained
    est = ReasoningEstimator(cfg, params, max_new_tokens=8)
    prompts = np.random.default_rng(8).integers(
        3, 100, size=(4, 12)).astype(np.int32)
    run = est.open_slots(prompts, tags=["a", "b"], segment_len=4)
    assert run.free_rows() == [2, 3]
    assert run.n_live == 2 and run.can_admit()


def test_slot_run_guards(tiny_trained):
    cfg, params, _ = tiny_trained
    est = ReasoningEstimator(cfg, params, max_new_tokens=8)
    prompts = np.ones((2, 10), np.int32)
    with pytest.raises(ValueError, match="segment_len"):
        est.open_slots(prompts, segment_len=0)
    with pytest.raises(ValueError, match="segment_len"):
        est.open_slots(prompts, segment_len=99)
    run = est.open_slots(prompts, segment_len=4)
    with pytest.raises(ValueError, match="free slots"):
        run.admit([("x", [1] * 5, 5)])
    with pytest.raises(ValueError, match="tags"):
        est.open_slots(prompts, tags=["a", "b", "c"], segment_len=4)


# ---------------------------------------------------------------------------
# Engine: segment-chunked refill stream
# ---------------------------------------------------------------------------
def test_stream_refill_matches_whole_retire(real_engine):
    """Refill-on and refill-off streams make identical routing decisions:
    token-derived fields bit-equal, confidences to f32 ulp (partial
    buckets run a different executable shape in whole-retire mode), and
    both match batch ``predict``."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:7]]
    ticks = [queries[:2], queries[2:3], queries[3:7]]
    ref = mk().predict(RouteRequest(queries))

    pools, scheds = {}, {}
    for refill in (False, True):
        sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
        pools[refill] = list(mk().predict_stream(
            (RouteRequest(t) for t in ticks), scheduler=sched,
            refill=refill, segment_len=3))
        scheds[refill] = sched
    assert len(pools[True]) == len(ticks)
    for field in ("y_hat", "len_hat", "well_formed", "cost_hat",
                  "pred_overhead"):
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(getattr(p, field)) for p in
                            pools[True]]),
            np.concatenate([np.asarray(getattr(p, field)) for p in
                            pools[False]]), err_msg=field)
    np.testing.assert_allclose(
        np.concatenate([p.p_hat for p in pools[True]]),
        np.concatenate([p.p_hat for p in pools[False]]),
        atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(
        np.concatenate([p.y_hat for p in pools[True]]), ref.y_hat)
    # both modes account decode-slot occupancy in SchedulerStats
    for refill in (False, True):
        st = scheds[refill].stats
        assert st.slot_steps_total > 0
        assert 0.0 < st.slot_occupancy <= 1.0
    # every scheduled prompt was delivered exactly once
    assert scheds[True].stats.emitted == scheds[True].stats.submitted


def test_stream_refill_cache_and_dedup(real_engine):
    """Cache writes land per parse group and in-flight duplicates share
    generations in refill mode, exactly as in the whole-retire stream."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:4]]
    ticks = [queries[:2], queries[:2], queries[2:4]]
    engine = mk()
    pools = list(engine.predict_stream(
        (RouteRequest(t) for t in ticks),
        scheduler=MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8))),
        refill=True, segment_len=3))
    # the duplicated middle tick spends no new estimator tokens
    assert int(pools[1].pred_overhead.sum()) == 0
    np.testing.assert_array_equal(pools[1].y_hat, pools[0].y_hat)
    # a later identical request is served from the cache, zero decode
    again = list(engine.predict_stream(
        iter([RouteRequest(queries[:2])]), refill=True))
    assert again[0].cache_hits == again[0].y_hat.size
    np.testing.assert_array_equal(again[0].y_hat, pools[0].y_hat)


def test_stream_refill_requires_slot_estimator(real_engine):
    """refill=True with an estimator lacking open_slots fails loudly."""
    mk, data = real_engine

    class Duck:
        def predict(self, prompts, rng=None):
            raise AssertionError("unreachable")

    engine = mk()
    engine.set_estimator(Duck(), "duck-v1")
    with pytest.raises(TypeError, match="open_slots"):
        list(engine.predict_stream(
            iter([RouteRequest([data.queries[int(data.test_qids[0])]])]),
            refill=True))


def test_stream_deadline_flush_bounds_queue_age(real_engine):
    """A fake clock drives the deadline: the lone first-tick query ships in
    a partially-filled bucket once max_queue_age expires instead of waiting
    for the stream to end."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:4]]
    now = [0.0]
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(64,)),
                                max_queue_age=1.0, clock=lambda: now[0])

    def ticks():
        yield RouteRequest(queries[:1])
        now[0] += 2.0                   # deadline expires between ticks
        yield RouteRequest(queries[1:])

    engine = mk()
    pools = list(engine.predict_stream(ticks(), scheduler=sched))
    assert sched.stats.deadline_flushes > 0
    assert sched.stats.partial_microbatches > 0
    ref = mk().predict(RouteRequest(queries))
    np.testing.assert_array_equal(
        np.concatenate([p.y_hat for p in pools]), ref.y_hat)
    np.testing.assert_allclose(
        np.concatenate([p.p_hat for p in pools]), ref.p_hat,
        atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Paged KV cache: sampler-level parity vs the dense oracle
# ---------------------------------------------------------------------------
def _paged_pair(cfg, params, prompts, lens, *, budget, pool_pages=64,
                page_size=8, kernel=None):
    """(dense state, paged state) over the same prompts — the paged kv_cap
    equals the dense cache width, so the XLA paged path is bit-identical
    by construction (gather -> slice -> the dense kernel)."""
    from repro.kernels.decode_attention import KernelType
    from repro.serving.kv_pool import KVPool
    dense = sampler.prefill_state(params, cfg, prompts,
                                  max_new_tokens=budget, prompt_lens=lens)
    pool = KVPool(n_pages=pool_pages, page_size=page_size)
    paged = sampler.prefill_state(params, cfg, prompts,
                                  max_new_tokens=budget, prompt_lens=lens,
                                  kv_pool=pool,
                                  kv_kernel=kernel or KernelType.XLA)
    return dense, paged, pool


def test_paged_prefill_and_segments_bit_identical(tiny_trained):
    """XLA paged decode == dense decode, bit for bit: ragged prompt lens,
    multiple scan segments, per-row positions."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(10)
    prompts = rng.integers(3, 100, size=(3, 20)).astype(np.int32)
    lens = [20, 13, 7]
    dense, paged, _ = _paged_pair(cfg, params, prompts, lens, budget=12)
    np.testing.assert_array_equal(np.asarray(dense.last_logits),
                                  np.asarray(paged.last_logits))
    for steps in (5, 4, 3):
        dense, g0, d0 = sampler.decode_segment(params, cfg, dense, steps)
        paged, g1, d1 = sampler.decode_segment(params, cfg, paged, steps)
        np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    np.testing.assert_array_equal(np.asarray(dense.positions),
                                  np.asarray(paged.positions))
    np.testing.assert_array_equal(np.asarray(dense.done),
                                  np.asarray(paged.done))


def test_paged_refill_segment_bit_identical(tiny_trained):
    """The fused refill+decode executable matches dense under paging: the
    refilled row restarts from its true length in fresh pages, the
    untouched rows keep decoding bit-identically."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(11)
    prompts = rng.integers(3, 100, size=(3, 16)).astype(np.int32)
    dense, paged, pool = _paged_pair(cfg, params, prompts, [16, 11, 16],
                                     budget=14)
    dense, g0, _ = sampler.decode_segment(params, cfg, dense, 4)
    paged, g1, _ = sampler.decode_segment(params, cfg, paged, 4)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    fresh = rng.integers(3, 100, size=(16,)).astype(np.int32)
    mask = np.array([False, True, False])
    mat = np.zeros((3, 16), np.int32)
    mat[1] = fresh
    refill = (mask, mat, np.array([1, 12, 1], np.int64))
    dense, g0, d0 = sampler.decode_segment(params, cfg, dense, 4,
                                           refill=refill)
    paged, g1, d1 = sampler.decode_segment(params, cfg, paged, 4,
                                           refill=refill)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_paged_refill_slots_bit_identical(tiny_trained):
    """``refill_slots`` (the standalone prefill-merge path) re-pages the
    refilled rows and matches the dense scatter bit-for-bit."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(12)
    prompts = rng.integers(3, 100, size=(3, 14)).astype(np.int32)
    dense, paged, _ = _paged_pair(cfg, params, prompts, None, budget=10)
    dense, _, _ = sampler.decode_segment(params, cfg, dense, 3)
    paged, _, _ = sampler.decode_segment(params, cfg, paged, 3)
    fresh = rng.integers(3, 100, size=(2, 14)).astype(np.int32)
    dense = sampler.refill_slots(params, cfg, dense, [0, 2], fresh,
                                 prompt_lens=[14, 9])
    paged = sampler.refill_slots(params, cfg, paged, [0, 2], fresh,
                                 prompt_lens=[14, 9])
    np.testing.assert_array_equal(np.asarray(dense.last_logits),
                                  np.asarray(paged.last_logits))
    for steps in (4, 3):
        dense, g0, d0 = sampler.decode_segment(params, cfg, dense, steps)
        paged, g1, d1 = sampler.decode_segment(params, cfg, paged, steps)
        np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_paged_pallas_kernel_matches_dense(tiny_trained):
    """The Pallas paged kernel (interpret mode on CPU) reproduces the dense
    token stream exactly; logits agree to kernel tolerance."""
    cfg, params, _ = tiny_trained
    from repro.kernels.decode_attention import KernelType
    rng = np.random.default_rng(13)
    prompts = rng.integers(3, 100, size=(3, 20)).astype(np.int32)
    dense, paged, _ = _paged_pair(cfg, params, prompts, [20, 13, 7],
                                  budget=10, kernel=KernelType.PALLAS)
    for steps in (5, 5):
        dense, g0, d0 = sampler.decode_segment(params, cfg, dense, steps)
        paged, g1, d1 = sampler.decode_segment(params, cfg, paged, steps)
        np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                                   atol=2e-5, rtol=2e-5)


def test_paged_pool_accounting_and_release(tiny_trained):
    """Pages flow free-list -> rows -> free-list: prompt pages allocated at
    admission, decode pages drawn from the row's reservation per segment,
    everything returned on retire; peaks track live tokens, not slots."""
    cfg, params, _ = tiny_trained
    from repro.serving.kv_pool import KVPool
    prompts = np.random.default_rng(14).integers(
        3, 100, size=(2, 16)).astype(np.int32)
    pool = KVPool(n_pages=12, page_size=8)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8,
                                  kv_pool=pool)
    # 16-token prompts: 2 pages allocated, 3 reserved (24-token worst case)
    assert pool.pages_in_use == 4 and pool.reserved == 2
    assert pool.live_tokens == 32
    state, _, _ = sampler.decode_segment(params, cfg, state, 8)
    assert pool.pages_in_use == 6 and pool.reserved == 0
    assert pool.live_tokens == 48 and pool.tokens_peak == 48
    pg = state.paged
    pg.retire_row(0)
    pg.retire_row(1)
    assert pool.pages_in_use == 0 and pool.available() == 12
    assert pool.live_tokens == 0 and pool.tokens_peak == 48
    assert (pg.table == pool.trash_page).all()


def test_paged_guards(tiny_trained):
    cfg, params, _ = tiny_trained
    from repro.serving.kv_pool import KVPool, check_paged_support
    prompts = np.random.default_rng(15).integers(
        3, 100, size=(2, 10)).astype(np.int32)
    # a pool too small for even one full-budget row fails loudly at admit
    with pytest.raises(ValueError, match="full-budget row"):
        sampler.prefill_state(params, cfg, prompts, max_new_tokens=64,
                              kv_pool=KVPool(n_pages=4, page_size=8))
    # page size wider than the whole cache is a config error
    with pytest.raises(ValueError, match="kv_page_size"):
        sampler.prefill_state(params, cfg, prompts, max_new_tokens=4,
                              kv_pool=KVPool(n_pages=8, page_size=64))
    # decoding past a row's kv_cap is caught host-side before the launch
    pool = KVPool(n_pages=16, page_size=8)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=6,
                                  kv_pool=pool)
    with pytest.raises(ValueError, match="paged row"):
        sampler.decode_segment(params, cfg, state, 7)
    # non-GQA backbones are rejected up front
    from repro.configs import get_config
    with pytest.raises(ValueError, match="paged"):
        check_paged_support(get_config("mamba2-1.3b").reduced())
    with pytest.raises(ValueError, match="paged"):
        check_paged_support(get_config("deepseek-v2-lite-16b").reduced())


def test_slot_run_paged_matches_dense(tiny_trained):
    """A paged SlotRun serves the same request set as the dense-horizon
    run with identical parses, and drains the pool on retirement."""
    cfg, params, _ = tiny_trained
    from repro.serving.kv_pool import KVPool
    est = ReasoningEstimator(cfg, params, max_new_tokens=8)
    rng = np.random.default_rng(16)
    prompts = rng.integers(3, 100, size=(4, 18)).astype(np.int32)
    extra = [("e", list(rng.integers(3, 100, size=18).astype(np.int32))),
             ("f", list(rng.integers(3, 100, size=18).astype(np.int32)))]

    def drive(**kw):
        run = est.open_slots(prompts, tags=["a", "b", "c", "d"],
                             segment_len=4, **kw)
        queue = list(extra)
        results = {}
        while not run.finished or queue:
            if queue and run.free_rows() and run.can_admit():
                n = min(len(queue), len(run.free_rows()))
                run.admit([(t, p, len(p)) for t, p in queue[:n]])
                del queue[:n]
            tags_done, batch = run.step()
            for i, t in enumerate(tags_done):
                results[t] = (batch.y_hat[i], batch.len_hat[i],
                              batch.pred_tokens[i], batch.p_conf[i])
        return results

    dense = drive()
    pool = KVPool(n_pages=32, page_size=8)
    paged = drive(kv_pool=pool)
    assert set(dense) == set(paged) == set("abcdef")
    for t in dense:
        assert dense[t][:3] == paged[t][:3], t
        np.testing.assert_allclose(dense[t][3], paged[t][3],
                                   atol=1e-6, rtol=1e-6, err_msg=t)
    # every page returned once the run retired
    assert pool.pages_in_use == 0 and pool.reserved == 0
    assert pool.pages_peak > 0 and pool.tokens_peak > 0


def test_slot_run_paged_admission_gates_on_pages(tiny_trained):
    """can_admit() in paged mode reflects the pool, not a horizon: a pool
    sized for the opening rows only defers further admissions until a row
    retires and frees its pages."""
    cfg, params, _ = tiny_trained
    from repro.serving.kv_pool import KVPool
    est = ReasoningEstimator(cfg, params, max_new_tokens=8)
    prompts = np.random.default_rng(17).integers(
        3, 100, size=(3, 16)).astype(np.int32)
    # exactly two worst-case rows: ceil((16+8)/8) = 3 pages each
    pool = KVPool(n_pages=6, page_size=8)
    run = est.open_slots(prompts, tags=["a"], kv_pool=pool, segment_len=4)
    assert run.horizon is None and run.deferral_reason == "pages"
    # rows 1-2 are free, but the live row's reservation leaves only 3
    # pages — one more worst-case row: admit it, then the pool is dry
    # even though a free slot remains
    assert run.can_admit()
    run.admit([("b", [5] * 10, 10)])
    assert not run.can_admit() and run.free_rows() == [2]
    with pytest.raises(ValueError, match="no room"):
        run.admit([("c", [5] * 4, 4)])
    while not run.finished:
        run.step()
    assert pool.pages_in_use == 0 and pool.reserved == 0


def test_stream_paged_matches_dense_refill(real_engine):
    """kv_paged engine streams route identically to the dense refill
    stream, account page stats at segment granularity, and never exceed
    the dense KV footprint."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:7]]
    ticks = [queries[:2], queries[2:3], queries[3:7]]

    pools, scheds = {}, {}
    for paged in (False, True):
        sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
        kw = ({"kv_paged": True, "kv_page_size": 8} if paged else {})
        pools[paged] = list(mk(refill=True, **kw).predict_stream(
            (RouteRequest(t) for t in ticks), scheduler=sched,
            segment_len=3))
        scheds[paged] = sched
    for field in ("y_hat", "len_hat", "well_formed", "cost_hat",
                  "pred_overhead"):
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(getattr(p, field)) for p in
                            pools[True]]),
            np.concatenate([np.asarray(getattr(p, field)) for p in
                            pools[False]]), err_msg=field)
    np.testing.assert_allclose(
        np.concatenate([p.p_hat for p in pools[True]]),
        np.concatenate([p.p_hat for p in pools[False]]),
        atol=1e-6, rtol=1e-6)
    st = scheds[True].stats
    assert st.kv_page_size == 8 and st.pages_peak > 0
    assert st.kv_peak_tokens > 0
    assert 0.0 <= st.page_fragmentation < 1.0
    # paged peak KV never exceeds the dense whole-horizon commitment
    assert st.kv_peak_tokens <= scheds[False].stats.kv_peak_tokens
    d = st.as_dict()
    assert d["kv_pages"]["peak"] == st.pages_peak


def test_stream_paged_requires_refill(real_engine):
    mk, data = real_engine
    engine = mk(kv_paged=True)
    with pytest.raises(ValueError, match="refill"):
        list(engine.predict_stream(
            iter([RouteRequest([data.queries[int(data.test_qids[0])]])]),
            refill=False))
