"""Continuous-batching serve runtime: per-row ragged decode, DecodeState
segments + slot refill, the double-buffered ServeRuntime, and the engine's
overlapped predict_stream (incl. ragged-length grid parity)."""
import numpy as np
import pytest

import jax

from repro.api import EngineConfig, RouteRequest, ScopeEngine
from repro.core.estimator import ReasoningEstimator
from repro.data.datasets import build_scope_data
from repro.serving import sampler
from repro.serving.runtime import ServeRuntime
from repro.serving.scheduler import BucketConfig, Microbatch, MicrobatchScheduler


# ---------------------------------------------------------------------------
# Per-row positions / ragged prompt lengths in the sampler
# ---------------------------------------------------------------------------
def test_generate_ragged_lengths_match_unpadded(tiny_trained):
    """Sub-bucket rows reproduce the unpadded run: token stream bit-exact,
    decision logits to f32 ulp (attention reductions span the bucket width,
    so last-bit equality across widths is not representable)."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(0)
    lens = [15, 20, 9, 20]
    L = max(lens)
    rows = [rng.integers(3, 100, size=ln).astype(np.int32) for ln in lens]
    padded = np.zeros((len(rows), L), np.int32)
    for i, r in enumerate(rows):
        padded[i, : len(r)] = r
    g, d = sampler.generate(params, cfg, padded, max_new_tokens=6,
                            prompt_lens=lens)
    for i, r in enumerate(rows):
        gi, di = sampler.generate(params, cfg, r[None], max_new_tokens=6)
        np.testing.assert_array_equal(g[i], gi[0], err_msg=f"row {i} tokens")
        np.testing.assert_allclose(d[i], di[0], atol=5e-6, rtol=1e-6,
                                   err_msg=f"row {i} decision logits")


def test_generate_full_length_rows_bit_identical_under_lens(tiny_trained):
    """A row whose true length equals the bucket is untouched by the
    per-row machinery: same batch, with vs without prompt_lens."""
    cfg, params, _ = tiny_trained
    prompts = np.random.default_rng(1).integers(
        3, 100, size=(3, 20)).astype(np.int32)
    g0, d0 = sampler.generate(params, cfg, prompts, max_new_tokens=5)
    g1, d1 = sampler.generate(params, cfg, prompts, max_new_tokens=5,
                              prompt_lens=[20, 20, 20])
    np.testing.assert_array_equal(g0, g1)
    np.testing.assert_array_equal(d0, d1)


def test_prompt_lens_validation(tiny_trained):
    cfg, params, _ = tiny_trained
    prompts = np.ones((2, 10), np.int32)
    with pytest.raises(ValueError, match="prompt_lens"):
        sampler.generate(params, cfg, prompts, prompt_lens=[5])
    with pytest.raises(ValueError, match="prompt_lens"):
        sampler.generate(params, cfg, prompts, prompt_lens=[5, 11])
    with pytest.raises(ValueError, match="prompt_lens"):
        sampler.generate(params, cfg, prompts, prompt_lens=[0, 10])


# ---------------------------------------------------------------------------
# DecodeState: chunked segments + slot refill
# ---------------------------------------------------------------------------
def test_decode_segments_match_one_shot(tiny_trained):
    cfg, params, _ = tiny_trained
    prompts = np.random.default_rng(2).integers(
        3, 100, size=(4, 18)).astype(np.int32)
    g1, d1 = sampler.generate(params, cfg, prompts, max_new_tokens=8)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
    gs, ds = [], []
    for steps in (3, 3, 2):
        state, g, d = sampler.decode_segment(params, cfg, state, steps)
        gs.append(np.asarray(g))
        ds.append(np.asarray(d))
    np.testing.assert_array_equal(np.concatenate(gs, axis=1), g1)
    np.testing.assert_array_equal(np.concatenate(ds, axis=1), d1)
    assert int(state.positions[0]) == 18 + 8 and state.used == 18 + 8


def test_decode_segments_match_one_shot_temperature(tiny_trained):
    """The sampling key is carried across segments — chunking must not
    change the stochastic stream."""
    cfg, params, _ = tiny_trained
    prompts = np.random.default_rng(3).integers(
        3, 100, size=(3, 16)).astype(np.int32)
    key = jax.random.PRNGKey(7)
    g1, _ = sampler.generate(params, cfg, prompts, max_new_tokens=8,
                             temperature=0.8, rng=key)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8,
                                  rng=key)
    gs = []
    for steps in (5, 3):
        state, g, _ = sampler.decode_segment(params, cfg, state, steps,
                                             temperature=0.8)
        gs.append(np.asarray(g))
    np.testing.assert_array_equal(np.concatenate(gs, axis=1), g1)


def test_refill_slot_between_segments(tiny_trained):
    """A drained slot refilled with a fresh prompt decodes exactly like a
    standalone run of that prompt, and the other rows are untouched."""
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(4)
    prompts = rng.integers(3, 100, size=(4, 18)).astype(np.int32)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
    state, _, _ = sampler.decode_segment(params, cfg, state, 4)

    new_prompt = rng.integers(3, 100, size=18).astype(np.int32)
    state = sampler.refill_slot(params, cfg, state, 2, new_prompt)
    assert int(state.positions[2]) == 18 and not bool(state.done[2])
    state, g, d = sampler.decode_segment(params, cfg, state, 4)

    # reference at the same batch size (a b=1 run picks a different gemm
    # path whose accumulation differs in the last ulp): token stream must
    # be bit-exact, decision logits to f32 ulp
    g_ref, d_ref = sampler.generate(params, cfg,
                                    np.repeat(new_prompt[None], 4, 0),
                                    max_new_tokens=4)
    np.testing.assert_array_equal(np.asarray(g)[2], g_ref[0])
    np.testing.assert_allclose(np.asarray(d)[2], d_ref[0],
                               atol=5e-6, rtol=1e-6)

    # untouched rows continue bit-identically vs a no-refill run
    s2 = sampler.prefill_state(params, cfg, prompts, max_new_tokens=8)
    s2, _, _ = sampler.decode_segment(params, cfg, s2, 4)
    s2, g2, _ = sampler.decode_segment(params, cfg, s2, 4)
    np.testing.assert_array_equal(
        np.asarray(g)[[0, 1, 3]], np.asarray(g2)[[0, 1, 3]])


def test_refill_and_segment_guards(tiny_trained):
    cfg, params, _ = tiny_trained
    prompts = np.ones((2, 10), np.int32)
    state = sampler.prefill_state(params, cfg, prompts, max_new_tokens=4)
    with pytest.raises(ValueError, match="out of range"):
        sampler.refill_slot(params, cfg, state, 5, [1] * 8)
    with pytest.raises(ValueError, match="no decode room"):
        sampler.refill_slot(params, cfg, state, 0, [1] * 14)
    with pytest.raises(ValueError, match="overruns the cache"):
        sampler.decode_segment(params, cfg, state, 5)
    with pytest.raises(ValueError, match="positive"):
        sampler.decode_segment(params, cfg, state, 0)


def test_generate_requires_rng_for_stochastic_decoding(tiny_trained):
    """temperature>0 without an explicit key must raise: the old
    PRNGKey(0) fallback sampled the identical stream on every call."""
    cfg, params, _ = tiny_trained
    prompts = np.ones((1, 8), np.int32)
    with pytest.raises(ValueError, match="rng"):
        sampler.generate(params, cfg, prompts, max_new_tokens=2,
                         temperature=0.7)
    # greedy keeps its deterministic no-key default
    g1, _ = sampler.generate(params, cfg, prompts, max_new_tokens=2)
    g2, _ = sampler.generate(params, cfg, prompts, max_new_tokens=2)
    np.testing.assert_array_equal(g1, g2)


def test_estimator_batch_requires_rng_for_stochastic(tiny_trained):
    cfg, params, _ = tiny_trained
    est = ReasoningEstimator(cfg, params, max_new_tokens=4)
    prompts = [[5] * 12, [6] * 12]
    with pytest.raises(ValueError, match="rng"):
        est.predict_batch(prompts, temperature=0.9)
    out = est.predict_batch(prompts, temperature=0.9,
                            rng=jax.random.PRNGKey(3))
    assert len(out) == 2


def test_dispatch_batch_empty_returns_empty_parse(tiny_trained):
    cfg, params, _ = tiny_trained
    est = ReasoningEstimator(cfg, params, max_new_tokens=4)
    handle = est.dispatch_batch([])
    assert handle.is_ready()
    assert len(handle.parse()) == 0        # not a concatenate crash


def test_ragged_prompt_lens_rejected_for_ssm_backbone():
    """SSM/conv prefill state consumes right-pad tokens (no per-row
    masking), so sub-bucket lengths must fail loudly, not corrupt."""
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("mamba2-1.3b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.ones((2, 10), np.int32)
    with pytest.raises(ValueError, match="attention-only"):
        sampler.prefill_state(params, cfg, prompts, max_new_tokens=2,
                              prompt_lens=[6, 10])
    # full-length rows carry no pad into the state: still allowed
    sampler.prefill_state(params, cfg, prompts, max_new_tokens=2,
                          prompt_lens=[10, 10])


# ---------------------------------------------------------------------------
# ServeRuntime: FIFO parse order, capacity, sync/overlap paths
# ---------------------------------------------------------------------------
class _Handle:
    def __init__(self, name, ready, log):
        self.name = name
        self._ready = ready
        self.log = log

    def is_ready(self):
        return self._ready

    def parse(self):
        self.log.append(("parse", self.name))
        return self.name


def _mb(name):
    return Microbatch(np.zeros((1, 4), np.int32), [name],
                      np.full((1,), 4, np.int32), (1, 4))


def test_serve_runtime_fifo_and_capacity():
    log, parsed = [], []

    def dispatch(mb):
        log.append(("dispatch", mb.tags[0]))
        return _Handle(mb.tags[0], ready=False, log=log)

    rt = ServeRuntime(dispatch, on_parsed=lambda mb, r: parsed.append(r),
                      max_pending=1)
    rt.dispatch([_mb("a")])
    assert log == [("dispatch", "a")] and len(rt) == 1
    rt.dispatch([_mb("b")])            # capacity: parse a BEFORE launching b
    assert log == [("dispatch", "a"), ("parse", "a"), ("dispatch", "b")]
    assert parsed == ["a"] and len(rt) == 1
    rt.finish()
    assert parsed == ["a", "b"] and len(rt) == 0
    assert rt.stats.dispatched == 2 and rt.stats.parsed == 2


def test_serve_runtime_sync_mode_parses_immediately():
    log, parsed = [], []
    rt = ServeRuntime(
        lambda mb: _Handle(mb.tags[0], ready=True, log=log),
        on_parsed=lambda mb, r: parsed.append(r), max_pending=0)
    rt.dispatch([_mb("a"), _mb("b")])
    assert parsed == ["a", "b"] and len(rt) == 0


def test_serve_runtime_poll_parses_only_ready():
    log, parsed = [], []
    handles = {}

    def dispatch(mb):
        h = _Handle(mb.tags[0], ready=False, log=log)
        handles[mb.tags[0]] = h
        return h

    rt = ServeRuntime(dispatch, on_parsed=lambda mb, r: parsed.append(r),
                      max_pending=2)
    rt.dispatch([_mb("a"), _mb("b")])
    assert rt.poll() == 0 and parsed == []
    handles["b"]._ready = True         # b done, but a (older) still running:
    assert rt.poll() == 0              # FIFO order is never violated
    handles["a"]._ready = True
    assert rt.poll() == 2 and parsed == ["a", "b"]
    # duck-typed results (no is_ready/parse) degrade to the sync path
    rt2 = ServeRuntime(lambda mb: mb.tags[0],
                       on_parsed=lambda mb, r: parsed.append(r),
                       max_pending=0)
    rt2.dispatch([_mb("c")])
    assert parsed[-1] == "c"


# ---------------------------------------------------------------------------
# Engine: overlapped stream parity + ragged length-grid parity
# ---------------------------------------------------------------------------
@pytest.fixture()
def real_engine(tiny_trained, world, retriever, library):
    cfg, params, _ = tiny_trained
    data = build_scope_data(world, n_queries=160, seed=9)

    def mk():
        return ScopeEngine.build(EngineConfig(
            estimator=ReasoningEstimator(cfg, params, max_new_tokens=6),
            retriever=retriever, library=library,
            models_meta={m: world.models[m] for m in data.models}))
    return mk, data


def test_stream_overlap_modes_bit_identical(real_engine):
    """Overlap changes when the host blocks, never what it observes: the
    double-buffered and synchronous streams see the same microbatches and
    must agree bit-for-bit; both match batch ``predict`` decisions (same
    tokens; confidences to f32 ulp — the one-big-batch shape reduces in a
    different order on this backend)."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:6]]
    ticks = [queries[:2], queries[2:3], queries[3:6]]
    ref = mk().predict(RouteRequest(queries))

    got = {}
    for overlap in (True, False):
        sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
        pools = list(mk().predict_stream(
            (RouteRequest(t) for t in ticks), scheduler=sched,
            overlap=overlap))
        got[overlap] = (np.concatenate([p.p_hat for p in pools]),
                        np.concatenate([p.y_hat for p in pools]))
    np.testing.assert_array_equal(got[True][0], got[False][0])
    np.testing.assert_array_equal(got[True][1], got[False][1])
    np.testing.assert_array_equal(got[True][1], ref.y_hat)
    np.testing.assert_allclose(got[True][0], ref.p_hat,
                               atol=1e-6, rtol=1e-6)


def test_stream_length_grid_matches_exact_fit(real_engine):
    """Ragged lengths under a configured prompt_lens grid: sub-bucket rows
    ride padded buckets yet the decisions match the unpadded exact-fit
    path — token-derived fields exactly, confidence to f32 ulp."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:5]]
    ticks = [queries[:2], queries[2:5]]
    ref = mk().predict(RouteRequest(queries))

    prompt_len = len(mk()._prepare(RouteRequest(queries[:1]), False)
                     .prompts[0])
    grid = BucketConfig(batch_sizes=(1, 2, 4, 8),
                        prompt_lens=(prompt_len + 7,))
    sched = MicrobatchScheduler(grid)
    pools = list(mk().predict_stream((RouteRequest(t) for t in ticks),
                                     scheduler=sched))
    assert sched.stats.pad_tokens > 0          # the grid really padded
    y = np.concatenate([p.y_hat for p in pools])
    lh = np.concatenate([p.len_hat for p in pools])
    wf = np.concatenate([p.well_formed for p in pools])
    cost = np.concatenate([p.cost_hat for p in pools])
    p_hat = np.concatenate([p.p_hat for p in pools])
    np.testing.assert_array_equal(y, ref.y_hat)
    np.testing.assert_array_equal(lh, ref.len_hat)
    np.testing.assert_array_equal(wf, ref.well_formed)
    np.testing.assert_array_equal(cost, ref.cost_hat)   # true prompt lens
    np.testing.assert_allclose(p_hat, ref.p_hat, atol=1e-6, rtol=1e-6)


def test_stream_deadline_flush_bounds_queue_age(real_engine):
    """A fake clock drives the deadline: the lone first-tick query ships in
    a partially-filled bucket once max_queue_age expires instead of waiting
    for the stream to end."""
    mk, data = real_engine
    queries = [data.queries[int(q)] for q in data.test_qids[:4]]
    now = [0.0]
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(64,)),
                                max_queue_age=1.0, clock=lambda: now[0])

    def ticks():
        yield RouteRequest(queries[:1])
        now[0] += 2.0                   # deadline expires between ticks
        yield RouteRequest(queries[1:])

    engine = mk()
    pools = list(engine.predict_stream(ticks(), scheduler=sched))
    assert sched.stats.deadline_flushes > 0
    assert sched.stats.partial_microbatches > 0
    ref = mk().predict(RouteRequest(queries))
    np.testing.assert_array_equal(
        np.concatenate([p.y_hat for p in pools]), ref.y_hat)
    np.testing.assert_allclose(
        np.concatenate([p.p_hat for p in pools]), ref.p_hat,
        atol=1e-6, rtol=1e-6)
