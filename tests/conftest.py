import os
import sys

# tests must see the single real CPU device (the dry-run flag is only ever
# set inside repro.launch.dryrun / subprocesses)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, for the pinned legacy references under benchmarks/
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest

from repro.core.fingerprint import FingerprintLibrary, build_anchor_set
from repro.core.retrieval import AnchorRetriever
from repro.data.datasets import build_scope_data, stratified_anchors
from repro.data.worldsim import World


@pytest.fixture(scope="session")
def world():
    return World(seed=0)


@pytest.fixture(scope="session")
def scope_data(world):
    return build_scope_data(world, n_queries=200, seed=0)


@pytest.fixture(scope="session")
def anchor_set(world):
    return build_anchor_set(world, stratified_anchors(world, n=80, seed=7))


@pytest.fixture(scope="session")
def library(world, anchor_set):
    lib = FingerprintLibrary(anchor_set)
    for m in world.pool:
        if m.seen:
            lib.onboard(world, m.name, seed=3)
    return lib


@pytest.fixture(scope="session")
def retriever(anchor_set):
    return AnchorRetriever(anchor_set)


@pytest.fixture(scope="session")
def tiny_trained(scope_data, library, retriever):
    """A briefly SFT-trained tiny estimator shared across tests."""
    import jax
    from repro.configs.scope_estimator import TINY
    from repro.models import model as M
    from repro.training.sft import build_sft_dataset, train_sft

    ds = build_sft_dataset(scope_data, library, retriever, cot=True,
                           max_examples=1200, seed=0)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    params, losses = train_sft(params, TINY, ds, steps=130, batch_size=32)
    return TINY, params, losses
